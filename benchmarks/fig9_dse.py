"""Fig. 9 reproduction: DSE quality vs iterations for 5 strategies.

NicePIM (DKL tuner) vs Random / SimulatedAnnealing / plain GP / GBT
("XGBoost" stand-in).  The evaluator maps reduced-scale versions of the
five workload DNNs (the full-size nets cost minutes per architecture —
the strategy ranking, which is what Fig. 9 shows, is preserved).
Quality metric matches the paper: mean reciprocal cost of the best 3
architectures seen so far, cost = EDP (alpha = beta = 1).

The strategies run as one :class:`repro.engine.campaign.Campaign`: a shared
content-addressed evaluation cache (costs are deterministic per config, so
sharing cannot bias any strategy — it only avoids re-mapping configs several
strategies visit), a shared Pareto front over (latency, energy, area), and
optional JSON checkpoint/resume via ``checkpoint=``.
"""

from __future__ import annotations

from repro.engine.campaign import Campaign
from repro.core.workloads import bert_base, googlenet, resnet50
from repro.obs.trace import Tracer

STRATEGIES = ("nicepim", "random", "simanneal", "gp", "xgboost")

MAPPER_KWARGS = dict(max_optim_iter=1, lm_cap=60, n_wr=3)


def _nets(tiny: bool = False):
    if tiny:
        return [googlenet(1, scale=8)]
    return [googlenet(1, scale=4), resnet50(1, scale=4),
            bert_base(1, seq=64, n_layers=2, n_heads=4)]


def run(iterations: int = 24, seed: int = 0, tiny: bool = False,
        strategies=STRATEGIES, checkpoint=None,
        evaluate_all_legal: bool = False,
        tuner_backend: str | None = None,
        trace: str | None = None,
        cache_db: str | None = None) -> list[dict]:
    # evaluate_all_legal=True maps EVERY legal proposal per iteration in one
    # multi-config pass (more observations per DKL refit); the default keeps
    # the paper's first-legal-only walk for Fig. 9 parity.
    # tuner_backend="loop" runs the tuner/GP models on the scalar per-step
    # reference path instead of the jitted scan engine (same-seed curves
    # match within float drift — tests/test_tuner_engine.py pins this).
    # trace="out.json" records every propose/map/schedule/evaluate span to a
    # Chrome-trace file loadable in Perfetto / chrome://tracing.
    # cache_db="evals.sqlite" swaps the in-memory evaluation table for a
    # PersistentEvalCache: reruns (and concurrent figure processes) dedupe
    # their mapper work against one durable content-addressed store.
    tracer = Tracer() if trace else None
    cache = None
    if cache_db:
        from repro.engine.cache import PersistentEvalCache
        cache = PersistentEvalCache(cache_db)
    campaign = Campaign(
        _nets(tiny), strategies, iterations=iterations, seed=seed,
        n_sample=512, evaluator_kwargs=dict(mapper_kwargs=dict(MAPPER_KWARGS)),
        strategy_kwargs=(dict(backend=tuner_backend) if tuner_backend
                         else None),
        checkpoint=checkpoint, evaluate_all_legal=evaluate_all_legal,
        cache=cache, tracer=tracer)
    out = campaign.run()
    if tracer is not None:
        tracer.save(trace)
    rows = []
    for name in strategies:
        res = out.results[name]
        q = res.quality_curve()
        best = res.best()
        rows.append({
            "table": "fig9", "strategy": name,
            "iterations": iterations,
            "quality_final": q[-1] if q else 0.0,
            "quality_mid": q[len(q) // 2] if q else 0.0,
            "best_cost": best.cost,
            "best_cfg": best.cfg.as_tuple(),
            "solve_s": out.wall_s.get(name, 0.0),
            "cpu_s": out.timings_s.get(name, 0.0),
            "curve": q,
        })
    from repro.engine.tuner_train import compiled_program_count
    rows.append({
        "table": "fig9", "strategy": "pareto",
        "iterations": iterations,
        "pareto_size": len(out.pareto),
        "pareto": out.pareto.to_jsonable(),
        "cache": out.cache_stats,
        "metrics": out.metrics,
        "programs": compiled_program_count(),
    })
    return rows


def main(iterations: int = 12, tiny: bool = False,
         trace: str | None = None, cache_db: str | None = None) -> None:
    rows = run(iterations=iterations, tiny=tiny, trace=trace,
               cache_db=cache_db)
    curves = [r for r in rows if r["strategy"] != "pareto"]
    base = [r for r in curves if r["strategy"] == "random"][0]["quality_final"]
    for r in curves:
        rel = r["quality_final"] / max(base, 1e-30)
        print(f"fig9_{r['strategy']},{r['solve_s'] * 1e6 / r['iterations']:.0f},"
              f"quality={r['quality_final']:.3e} vs_random={rel:.2f}x")
    pareto = next(r for r in rows if r["strategy"] == "pareto")
    cache = pareto["cache"]
    total = cache["hits"] + cache["misses"]
    print(f"# eval cache: {cache['hits']}/{total} hits "
          f"({cache['entries']} entries); "
          f"compiled programs: {sum(pareto['programs'].values())}")
    if trace:
        print(f"# chrome trace written to {trace}")


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--iterations", type=int, default=12)
    ap.add_argument("--tiny", action="store_true")
    ap.add_argument("--trace", default=None, metavar="OUT.json",
                    help="write a Chrome-trace of the campaign here")
    ap.add_argument("--cache-db", default=None, metavar="EVALS.sqlite",
                    help="persistent cross-process evaluation cache: "
                         "reruns serve repeated configs from this sqlite "
                         "store instead of re-mapping them")
    a = ap.parse_args()
    main(iterations=a.iterations, tiny=a.tiny, trace=a.trace,
         cache_db=a.cache_db)
