"""Fig. 9 reproduction: DSE quality vs iterations for 5 strategies.

NicePIM (DKL tuner) vs Random / SimulatedAnnealing / plain GP / GBT
("XGBoost" stand-in).  The evaluator maps reduced-scale versions of the
five workload DNNs (the full-size nets cost minutes per architecture —
the strategy ranking, which is what Fig. 9 shows, is preserved).
Quality metric matches the paper: mean reciprocal cost of the best 3
architectures seen so far, cost = EDP (alpha = beta = 1).
"""

from __future__ import annotations

import time

from repro.core.dse import WorkloadEvaluator, run_dse
from repro.core.surrogates import make_strategy
from repro.core.workloads import bert_base, googlenet, resnet50

STRATEGIES = ("nicepim", "random", "simanneal", "gp", "xgboost")


def make_evaluator(tiny: bool = False) -> WorkloadEvaluator:
    if tiny:
        nets = [googlenet(1, scale=8)]
    else:
        nets = [googlenet(1, scale=4), resnet50(1, scale=4),
                bert_base(1, seq=64, n_layers=2, n_heads=4)]
    return WorkloadEvaluator(
        nets, mapper_kwargs=dict(max_optim_iter=1, lm_cap=60, n_wr=3))


def run(iterations: int = 24, seed: int = 0, tiny: bool = False,
        strategies=STRATEGIES) -> list[dict]:
    rows = []
    # one shared evaluator: costs are deterministic per config, so sharing
    # the cache cannot bias any strategy — it only avoids re-mapping configs
    # that several strategies happen to visit
    evaluator = make_evaluator(tiny)
    for name in strategies:
        strat = make_strategy(name, seed=seed, n_sample=512)
        t0 = time.time()
        res = run_dse(strat, evaluator, iterations=iterations)
        q = res.quality_curve()
        best = res.best()
        rows.append({
            "table": "fig9", "strategy": name,
            "iterations": iterations,
            "quality_final": q[-1] if q else 0.0,
            "quality_mid": q[len(q) // 2] if q else 0.0,
            "best_cost": best.cost,
            "best_cfg": best.cfg.as_tuple(),
            "solve_s": time.time() - t0,
            "curve": q,
        })
    return rows


def main(iterations: int = 12, tiny: bool = False) -> None:
    rows = run(iterations=iterations, tiny=tiny)
    base = [r for r in rows if r["strategy"] == "random"][0]["quality_final"]
    for r in rows:
        rel = r["quality_final"] / max(base, 1e-30)
        print(f"fig9_{r['strategy']},{r['solve_s'] * 1e6 / r['iterations']:.0f},"
              f"quality={r['quality_final']:.3e} vs_random={rel:.2f}x")


if __name__ == "__main__":
    main()
