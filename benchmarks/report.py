"""Assemble EXPERIMENTS.md from dry-run JSONs + benchmark results.

Sections:
  §Dry-run          — compile status, memory per device, collective schedule
  §Roofline         — three terms per (arch x shape x mesh), bottleneck, MFU
  §Paper            — Fig. 9/10/11/12 reproductions vs the paper's claims
  §Sharded-campaign — BENCH_9 mega-campaign speedup + kill/resume contract
  §Overlap          — BENCH_10 overlapped-executor speedup + parity contract
  §Perf-trajectory  — named regression gates per BENCH_*.json artifact
  §Perf             — hillclimb log (benchmarks/perf_log.py entries)
"""

from __future__ import annotations

import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
DRYRUN_DIR = ROOT / "experiments" / "dryrun"
PERF_DIR = ROOT / "experiments" / "perf"
PAPER_JSON = ROOT / "experiments" / "paper_benchmarks.json"
OUT = ROOT / "EXPERIMENTS.md"


def _fmt_bytes(b: float) -> str:
    for unit in ("B", "KiB", "MiB", "GiB", "TiB"):
        if abs(b) < 1024 or unit == "TiB":
            return f"{b:.2f}{unit}"
        b /= 1024
    return f"{b:.1f}"


def load_dryrun() -> list[dict]:
    if not DRYRUN_DIR.exists():
        return []
    return sorted((json.loads(p.read_text())
                   for p in DRYRUN_DIR.glob("*.json")),
                  key=lambda d: (d["arch"], d["shape"], d["mesh"]))


def dryrun_section(cells: list[dict]) -> str:
    lines = [
        "## §Dry-run",
        "",
        "`.lower().compile()` on the production meshes (single-pod 16x16 = "
        "256 chips; multi-pod 2x16x16 = 512 chips) with 512 host placeholder "
        "devices. `mem/dev` = args + temps + outputs - aliased from "
        "`compiled.memory_analysis()` of the SPMD-partitioned (per-device) "
        "program.",
        "",
        "| arch | shape | mesh | status | compile_s | mem/dev | collectives (per-chip link bytes) |",
        "|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        if c.get("status") != "ok":
            lines.append(f"| {c['arch']} | {c['shape']} | {c['mesh']} | "
                         f"FAIL: {c.get('error', '?')[:60]} | | | |")
            continue
        mem = c["memory"]
        per_dev = (mem.get("argument_size_in_bytes", 0)
                   + mem.get("temp_size_in_bytes", 0)
                   + mem.get("output_size_in_bytes", 0)
                   - mem.get("alias_size_in_bytes", 0))
        colls = ", ".join(f"{k.split('-')[-1]}={_fmt_bytes(v)}"
                          for k, v in c["collectives"].items() if v)
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} | ok | "
            f"{c['compile_s']:.1f} | {_fmt_bytes(per_dev)} | {colls or '-'} |")
    skips = _skips()
    if skips:
        lines += ["", "Skipped cells (documented in DESIGN.md "
                      "§Arch-applicability):", ""]
        for a, s, why in skips:
            lines.append(f"- `{a}` x `{s}`: {why}")
    return "\n".join(lines)


def _skips():
    try:
        import sys
        sys.path.insert(0, str(ROOT / "src"))
        from repro.configs.base import skipped_cells
        return skipped_cells()
    except Exception:
        return []


def roofline_section(cells: list[dict]) -> str:
    lines = [
        "## §Roofline",
        "",
        "Terms per the spec: compute = HLO_FLOPs/(chips*197 TF/s), memory = "
        "HLO_bytes/(chips*819 GB/s), collective = per-chip link bytes / "
        "50 GB/s. FLOPs/bytes come from the unrolled cost-fidelity pass "
        "(XLA cost_analysis counts while bodies once); `useful` = "
        "MODEL_FLOPS/HLO_FLOPs; `frac` = ideal-compute-time / max(term).",
        "",
        "| arch | shape | mesh | compute_s | memory_s | collective_s | "
        "bottleneck | useful | frac | note |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        if c.get("status") != "ok":
            continue
        r = c["roofline"]
        lines.append(
            f"| {c['arch']} | {c['shape']} | {c['mesh']} | "
            f"{r['compute_s']:.4f} | {r['memory_s']:.4f} | "
            f"{r['collective_s']:.4f} | **{r['bottleneck']}** | "
            f"{r['useful_ratio']:.2f} | {r['roofline_fraction']:.4f} | "
            f"{r.get('note', '')[:60]} |")
    return "\n".join(lines)


def paper_section() -> str:
    if not PAPER_JSON.exists():
        return "## §Paper-experiments\n\n(run `python -m benchmarks.run`)"
    rows = json.loads(PAPER_JSON.read_text())
    lines = ["## §Paper-experiments", ""]
    fig10 = [r for r in rows if r.get("table") == "fig10"]
    if fig10:
        avg = [r for r in fig10 if r.get("net") == "all"]
        lines += ["### Fig. 10 — PIM-Mapper vs sequential baseline "
                  "(paper: −37 % latency / −28 % energy avg)", "",
                  "| system | net | mapper lat (ms) | base lat (ms) | ΔLat | "
                  "mapper E (uJ) | base E (uJ) | ΔE |",
                  "|---|---|---|---|---|---|---|---|"]
        for r in fig10:
            if r.get("net") == "all":
                continue
            lines.append(
                f"| {r['system']} | {r['net']} | "
                f"{r['mapper_latency_ms']:.2f} | "
                f"{r['baseline_latency_ms']:.2f} | "
                f"{-r['latency_reduction']:.0%} | "
                f"{r['mapper_energy_uj']:.0f} | "
                f"{r['baseline_energy_uj']:.0f} | "
                f"{-r['energy_reduction']:.0%} |")
        if avg:
            lines.append(f"| **avg** | | | | "
                         f"**{-avg[0]['latency_reduction']:.0%}** | | | "
                         f"**{-avg[0]['energy_reduction']:.0%}** |")
        lines.append("")
    fig9 = [r for r in rows
            if r.get("table") == "fig9" and "quality_final" in r]
    if fig9:
        lines += ["### Fig. 9 — DSE quality (mean 1/cost of best-3; "
                  "higher is better)", "",
                  "| strategy | final quality | vs random |", "|---|---|---|"]
        base = next((r["quality_final"] for r in fig9
                     if r["strategy"] == "random"), 1e-30)
        for r in fig9:
            lines.append(f"| {r['strategy']} | {r['quality_final']:.3e} | "
                         f"{r['quality_final'] / max(base, 1e-30):.2f}x |")
        lines.append("")
    par = next((r for r in rows if r.get("table") == "fig9"
                and r.get("strategy") == "pareto"), None)
    if par:
        lines += [f"Campaign Pareto front: {par['pareto_size']} points; "
                  f"eval cache: {par['cache']['hits']} hits / "
                  f"{par['cache']['misses']} misses.", ""]
        lines += _campaign_metrics(par)
    eng = [r for r in rows if r.get("table") == "engine"]
    if eng:
        r = eng[-1]
        lines += ["### Engine — batched vs scalar cost-model throughput", "",
                  "| path | configs/sec | speedup |", "|---|---|---|",
                  f"| scalar per-candidate loop | "
                  f"{r['scalar_configs_per_s']:.1f} | 1.0x |",
                  f"| batched engine ({r['n_configs']} cfgs x "
                  f"{r['n_layers']} part-layers) | "
                  f"{r['batched_configs_per_s']:.1f} | "
                  f"{r['speedup']:.1f}x |", ""]
    mapper = [r for r in rows if r.get("table") == "mapper"]
    if mapper:
        r = mapper[-1]
        lines += ["### Mapper — batched vs scalar candidate costing", "",
                  f"(LM x WR) candidate points per second over "
                  f"{r['n_sweeps']} DL-alternation sweeps of "
                  f"{r['n_layers']} layers on a "
                  f"{r['region'][0]}x{r['region'][1]} region "
                  f"(contract: >=10x).", "",
                  "| path | candidates/sec | speedup |", "|---|---|---|",
                  f"| scalar per-candidate loop | "
                  f"{r['scalar_cands_per_s']:.0f} | 1.0x |",
                  f"| batched backend | {r['batched_cands_per_s']:.0f} | "
                  f"{r['speedup']:.1f}x |", "",
                  f"End-to-end `PimMapper.map` (googlenet): "
                  f"{r['map_speedup']:.2f}x faster batched.", ""]
    multi = [r for r in rows if r.get("table") == "mapper_multi"]
    if multi:
        r = multi[-1]
        lines += ["### Mapper — multi-config batched mapping "
                  "(`PimMapper.map_many`)", "",
                  f"End-to-end maps/sec over a batch of {r['batch']} "
                  f"proposal configs (googlenet, one optimization pass); "
                  f"contract: >=3x vs the scalar sequential reference at "
                  f"batch >= 8.", "",
                  "| path | maps/sec | speedup |", "|---|---|---|",
                  f"| scalar sequential per-config `map()` | "
                  f"{r['batch'] / r['scalar_seq_s']:.2f} | 1.0x |",
                  f"| batched sequential per-config `map()` | "
                  f"{r['maps_per_s_seq']:.2f} | "
                  f"{r['scalar_seq_s'] / r['seq_s']:.2f}x |",
                  f"| `map_many` (one multi-config batch) | "
                  f"{r['maps_per_s_batched']:.2f} | "
                  f"{r['speedup']:.2f}x |", ""]
    tuner = [r for r in rows if r.get("table") == "tuner"]
    if tuner:
        r = tuner[-1]
        progs = ", ".join(f"{k}={v}" for k, v in r["programs"].items() if v)
        lines += ["### Tuner — jitted scan engine vs scalar loop "
                  "(propose + fit per DSE iteration)", "",
                  f"Growing-dataset schedule to {r['n_obs_final']} "
                  f"observations, {r['n_sample']} candidates/propose; "
                  f"throughput measured at >={r['min_obs']} observations "
                  f"(contract: >=5x; pow2-bucket program bound "
                  f"{r['program_bound']} per entry point).", "",
                  "| path | iterations/sec | speedup |", "|---|---|---|",
                  f"| scalar loop (per-step dispatch, retrace per size) | "
                  f"{r['loop_iters_per_s']:.2f} | 1.0x |",
                  f"| scan engine (pow2-bucketed, fused propose) | "
                  f"{r['engine_iters_per_s']:.2f} | "
                  f"{r['speedup']:.1f}x |", "",
                  f"XLA programs compiled by the engine across the run: "
                  f"{progs or 'none (warm cache)'}.", ""]
    sched = [r for r in rows if r.get("table") == "scheduler"]
    if sched:
        tot = next((r for r in sched if r["case"] == "batched_total"), None)
        lines += ["### Scheduler — jitted scan engine vs host-Python loop "
                  "(joint 2-opt solves)", "",
                  "Fig. 12 singles at the paper budget; batched = one "
                  "pow2-bucketed `schedule_many` call over chunk-scaled "
                  "problem variants (contract: >=5x batched solve "
                  "throughput; scan objective <= loop on every array). "
                  "The 16x16 array's 960 dense link loads made the scan "
                  "memory-bound on CPU before PR 7 (~0.9x vs loop, 239 ms "
                  "per solve); the int16 flip-cumsum + streamed delta "
                  "scoring hold it at >=1x (asserted; ~1.7x / ~107 ms "
                  "measured on the `jnp-dense` path — `pallas-stream` is "
                  "the TPU path).", "",
                  "| case | path | scan (ms) | loop (ms) | speedup |",
                  "|---|---|---|---|---|"]
        for r in sched:
            if r["case"] == "batched_total":
                continue
            tag = (f"{r['case']} (batch {r['batch']})"
                   if "batch" in r else r["case"])
            lines.append(f"| {tag} | {r.get('path', '-')} | "
                         f"{r['scan_s'] * 1e3:.0f} | "
                         f"{r['loop_s'] * 1e3:.0f} | "
                         f"{r['speedup']:.1f}x |")
        if tot:
            lines.append(f"| **batched total ({tot['n_solves']} solves)** | "
                         f"- | "
                         f"{tot['scan_s'] * 1e3:.0f} | "
                         f"{tot['loop_s'] * 1e3:.0f} | "
                         f"**{tot['speedup']:.1f}x** |")
        lines.append("")
    fig11 = [r for r in rows if r.get("table") == "fig11"]
    if fig11:
        lines += ["### Fig. 11 — throughput vs DDAM-lite "
                  "(paper: +11 % avg, ~10x latency gap)", "",
                  "| net | thr gain | DDAM/mapper latency |", "|---|---|---|"]
        for r in fig11:
            lines.append(f"| {r['net']} | {r['throughput_gain']:+.0%} | "
                         f"{r['latency_ratio']:.1f}x |")
        lines.append("")
    fig12 = [r for r in rows if r.get("table") == "fig12"]
    if fig12:
        lines += ["### Fig. 12 — data-sharing schedulers "
                  "(latency normalized to ILP)", "",
                  "Ordering (ILP <= TSP <= SHP) reproduces; magnitudes are "
                  "muted vs the paper because our NoC model charges "
                  "aggregate link load (the paper's Eq. 4 objective) while "
                  "BookSim's flit-level simulation adds serialization and "
                  "in-flight contention that penalize SHP/TSP further.", "",
                  "| array | ilp | tsp | shp |", "|---|---|---|---|"]
        arrays = sorted({r["array"] for r in fig12},
                        key=lambda a: int(a.split("x")[0]))
        for a in arrays:
            sub = {r["method"]: r for r in fig12 if r["array"] == a}
            lines.append(
                f"| {a} | 1.00 | {sub['tsp']['norm_latency']:.2f} | "
                f"{sub['shp']['norm_latency']:.2f} |")
    return "\n".join(lines)


def _fmt_metric(v) -> str:
    if isinstance(v, dict):  # histogram summary {count, sum, min, max, mean}
        return (f"n={v.get('count', 0)} mean={v.get('mean', 0):.3g} "
                f"[{v.get('min', 0):.3g}, {v.get('max', 0):.3g}]")
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def _campaign_metrics(par: dict) -> list[str]:
    """Selected registry metrics from the Fig. 9 campaign's pareto row."""
    metrics = par.get("metrics") or {}
    if not metrics:
        return []
    keep = [k for k in sorted(metrics)
            if k.startswith(("eval_cache.", "pareto.", "campaign."))
            or k.endswith((".best_cost", ".legal_fraction"))
            or k.startswith("tuner.bucket_fill")
            or k.startswith("scheduler.bucket_fill")]
    lines = ["Campaign telemetry (metrics registry snapshot):", "",
             "| metric | value |", "|---|---|"]
    for k in keep:
        lines.append(f"| `{k}` | {_fmt_metric(metrics[k])} |")
    progs = par.get("programs") or {}
    if progs:
        lines.append(f"| `xla.programs` (total) | {sum(progs.values())} |")
    lines.append("")
    return lines


def campaign_section() -> str:
    """§Sharded-campaign: the BENCH_9 mega-campaign contract."""
    f = ROOT / "experiments" / "BENCH_9.json"
    lines = ["## §Sharded-campaign", ""]
    if not f.exists():
        return "\n".join(lines + [
            "(run `python -m benchmarks.campaign_throughput`)"])
    try:
        b = json.loads(f.read_text())
    except json.JSONDecodeError:
        return "\n".join(lines + ["(BENCH_9.json unreadable)"])
    by_name = {r["name"]: r for r in b.get("benchmarks", [])}
    gate = b.get("gates", {}).get("campaign_sharded_speedup", {})
    lines += [
        "Multi-tenant DSE service (`repro.engine.sharded.ShardedCampaign`): "
        "repeated tenant submissions on a 4-device `config` mesh with async "
        "wave overlap and one shared `PersistentEvalCache`, vs the same "
        "submissions run sequentially single-stream.  Observation streams "
        "and the Pareto front are asserted identical; a mid-campaign "
        "`os._exit` kill resumes with zero re-evaluated points "
        "(replay-by-re-proposal against the durable sqlite table).", "",
        "| case | result |", "|---|---|",
    ]
    sh = by_name.get("campaign_sharded")
    if sh:
        lines.append(f"| sharded vs single-stream | {sh['derived']} "
                     f"({b.get('mode', '?')} mode, gate floor "
                     f"{gate.get('value', 0):.2f} - "
                     f"{gate.get('tolerance', 0):.0%}) |")
    kr = by_name.get("campaign_kill_resume")
    if kr:
        lines.append(f"| kill-and-resume | {kr['derived']} |")
    return "\n".join(lines + [""])


def overlap_section() -> str:
    """§Overlap: the BENCH_10 overlapped-wave-executor contract."""
    f = ROOT / "experiments" / "BENCH_10.json"
    lines = ["## §Overlap", ""]
    if not f.exists():
        return "\n".join(lines + [
            "(run `python -m benchmarks.overlap_throughput`)"])
    try:
        b = json.loads(f.read_text())
    except json.JSONDecodeError:
        return "\n".join(lines + ["(BENCH_10.json unreadable)"])
    by_name = {r["name"]: r for r in b.get("benchmarks", [])}
    gate = b.get("gates", {}).get("overlap_speedup", {})
    lines += [
        "Overlapped wave executor (`repro.engine.overlap.OverlapExecutor`): "
        "`map_many` paired cost sweeps dispatched async so wave *k*'s "
        "device costing is in flight while the host runs wave *k−1*'s "
        "backtracking / scheduling, with iteration *k+1*'s fused propose "
        "chain double-buffered behind iteration *k*'s ingest.  Observation "
        "streams and Pareto fronts vs the serial executor are asserted "
        "identical bit for bit; the throughput contract is >=1.3x warm "
        "iterations on a multi-core host (break-even on single-core — "
        "there is no second core to hide latency on).", "",
        "| case | result |", "|---|---|",
    ]
    ov = by_name.get("overlap_warm_iter")
    if ov:
        lines.append(f"| overlapped vs serial warm campaign | "
                     f"{ov['derived']} ({b.get('mode', '?')} mode, gate "
                     f"{gate.get('value', 0):.2f} - "
                     f"{gate.get('tolerance', 0):.0%}) |")
    return "\n".join(lines + [""])


def bench_section() -> str:
    """§Perf-trajectory: the named gates in each BENCH_*.json artifact."""
    lines = ["## §Perf-trajectory", ""]
    files = sorted((ROOT / "experiments").glob("BENCH_*.json"))
    if not files:
        return "\n".join(lines + [
            "(no BENCH artifacts yet — run `python -m benchmarks.run`)"])
    lines += [
        "Machine-readable perf artifacts written by `benchmarks.run` and "
        "gated in CI by `benchmarks.bench_gate` (a gate regresses when it "
        "falls below `baseline * (1 - tolerance)`).", "",
        "| artifact | mode | gate | value | tolerance |", "|---|---|---|---|---|"]
    for f in files:
        try:
            b = json.loads(f.read_text())
        except json.JSONDecodeError:
            lines.append(f"| {f.name} | ? | (unreadable) | | |")
            continue
        gates = b.get("gates", {})
        for i, (name, g) in enumerate(sorted(gates.items())):
            tag = f.name if i == 0 else ""
            mode = b.get("mode", "?") if i == 0 else ""
            lines.append(f"| {tag} | {mode} | `{name}` | "
                         f"{g['value']:.2f} | {g.get('tolerance', 0):.0%} |")
        secs = b.get("sections_s", {})
        if secs:
            total = sum(secs.values())
            lines.append(f"| | | _wall_ | {total:.0f}s | |")
    return "\n".join(lines + [""])


def perf_section() -> str:
    lines = ["## §Perf", ""]
    if not PERF_DIR.exists():
        return "\n".join(lines + ["(no hillclimb entries yet)"])
    entries = sorted(PERF_DIR.glob("*.md"))
    for e in entries:
        lines.append(e.read_text().rstrip())
        lines.append("")
    return "\n".join(lines)


def build() -> str:
    cells = load_dryrun()
    parts = [
        "# EXPERIMENTS",
        "",
        "Generated by `benchmarks/report.py` from `experiments/` artifacts. "
        "Hardware constants: TPU v5e-class — 197 TFLOP/s bf16, 819 GB/s HBM, "
        "50 GB/s/link ICI.",
        "",
        dryrun_section(cells),
        "",
        roofline_section(cells),
        "",
        paper_section(),
        "",
        campaign_section(),
        "",
        overlap_section(),
        "",
        bench_section(),
        "",
        perf_section(),
    ]
    return "\n".join(parts) + "\n"


def main() -> None:
    OUT.write_text(build())
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
