"""Fig. 11 reproduction: PIM-Mapper vs DDAM-lite pipeline mapping.

DDAM optimizes steady-state throughput by pipelining contiguous stages over
array regions; the paper reports PIM-Mapper with ~11 % better throughput on
average and ~10x better single-sample latency.  Batch sweep 1..16 as in the
paper, best throughput per framework reported.
"""

from __future__ import annotations

import time

from repro.core.baseline import DdamMapper
from repro.core.hardware import PAPER_4X4
from repro.core.mapper import PimMapper, evaluate_mapping
from repro.core.workloads import darknet53, googlenet, resnet50


def run(fast: bool = True, batches=(1, 4, 16)) -> list[dict]:
    scale = 4 if fast else 1
    rows = []
    for build in (googlenet, resnet50, darknet53):
        hw = PAPER_4X4
        best_m = best_d = None
        for b in batches:
            g = build(b, scale=scale)
            rep = evaluate_mapping(PimMapper(hw, max_optim_iter=1,
                                             lm_cap=80).map(g))
            thr_m = b / rep.latency_s
            if best_m is None or thr_m > best_m[0]:
                best_m = (thr_m, rep.latency_s / b, rep.energy_pj / b)
            pres = DdamMapper(hw).map(g)
            thr_d = pres.throughput_sps * b   # throughput per batch run
            if best_d is None or thr_d > best_d[0]:
                best_d = (thr_d, pres.latency_s, pres.energy_pj / b)
        rows.append({
            "table": "fig11", "net": build.__name__,
            "mapper_throughput_sps": best_m[0],
            "ddam_throughput_sps": best_d[0],
            "throughput_gain": best_m[0] / best_d[0] - 1,
            "mapper_latency_ms": best_m[1] * 1e3,
            "ddam_latency_ms": best_d[1] * 1e3,
            "latency_ratio": best_d[1] / best_m[1],
        })
    return rows


def main(fast: bool = True) -> None:
    for r in run(fast=fast):
        print(f"fig11_{r['net']},{r['mapper_latency_ms'] * 1e3:.1f},"
              f"thr_gain={r['throughput_gain']:+.1%} "
              f"lat_ratio={r['latency_ratio']:.1f}x")


if __name__ == "__main__":
    main()
