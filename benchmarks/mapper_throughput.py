"""Mapper candidate-costing throughput: batched engine vs scalar loop.

The PIM-Mapper's hot path is producing per-(layer, region, DL) candidate
tables — every (LM x WR) point needs a node cost (``part_layer_cost``) plus a
communication estimate.  The scalar path costs them one Python call at a
time; the batched backend pushes all node costs of a sweep through
``engine.batch_part_cost`` and the communication axis through the vectorized
``partition.comm_estimate_batch``.

The measured workload mirrors ``PimMapper.map``'s steady state: several DL
alternation sweeps over the same (layer x region-shape) key set — exactly
what ``_solve_sm_lm_wr`` + ``_optimize_dl`` generate per mapping pass, and
what DSE campaigns repeat per hardware config.  A full cold sweep is included
in the timing (structures and jit caches amortize across sweeps, as they do
in a real mapper run, but nothing layer-specific is pre-warmed).

The acceptance bar is >=10x candidate-costing throughput; ``run(assert_10x=
True)`` (the default outside ``--smoke``) enforces it so the harness fails
loudly on regressions.  End-to-end ``PimMapper.map`` time on a real net is
reported as a secondary, unasserted number.
"""

from __future__ import annotations

import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.core import mapper as mapper_mod
from repro.core.hardware import PAPER_16X16, PAPER_BEST
from repro.core.layout import DataLayout
from repro.core.mapper import PimMapper, clear_mapper_caches
from repro.core.workloads import googlenet, resnet50

# the DL-sweep pattern of _optimize_dl: per sweep, a fresh (DLi, DLo) pair
SWEEPS = (
    (None, None),                                # iteration 1: default DLs
    (DataLayout("BHWC"), DataLayout("BCHW", 4)),
    (DataLayout("BCHW", 8), DataLayout("BHWC")),
)


def _keys(pm: PimMapper, layers, region, sweep):
    h, w = region
    din, dout = sweep
    return [pm._cand_key(l, h, w, din or pm._default_dl(l.C),
                         dout or pm._default_dl(l.K)) for l in layers]


def run(n_layers: int = 40, region=(8, 16), hw=PAPER_16X16,
        n_sweeps: int = 3, assert_10x: bool = True,
        map_scale: int = 4) -> list[dict]:
    layers = [l for g in (googlenet(1, scale=2), resnet50(1, scale=2))
              for l in g.layers if l.is_heavy][:n_layers]
    pm = PimMapper(hw, backend="batched")
    sweeps = [SWEEPS[i % len(SWEEPS)] for i in range(n_sweeps)]
    key_sets = [_keys(pm, layers, region, s) for s in sweeps]

    # warm the XLA programs (compile is one-off per process, not throughput)
    pm._prefetch_candidates(key_sets[0])

    def _best_of(n, body):
        # best-of-n: the batched sweep is short (~0.3 s), so a single
        # scheduler hiccup would otherwise dominate the measured ratio
        best = float("inf")
        for _ in range(n):
            clear_mapper_caches()
            t0 = time.perf_counter()
            body()
            best = min(best, time.perf_counter() - t0)
        return best

    # ---- scalar per-candidate loop ----------------------------------------
    def scalar_sweep():
        for keys in key_sets:
            for k in keys:
                mapper_mod._layer_candidates(*k)
    scalar_s = _best_of(2, scalar_sweep)

    # ---- batched engine sweep ---------------------------------------------
    def batched_sweep():
        for keys in key_sets:
            pm._prefetch_candidates(keys)
    batched_s = _best_of(3, batched_sweep)
    speedup = scalar_s / batched_s

    # (LM x WR) points costed per sweep — the throughput unit
    n_cands = sum(
        len(mapper_mod._cand_struct(hw, k[1], k[2], k[3], k[6], k[7])
            .pair_lm_of) for k in key_sets[0])

    # ---- secondary: end-to-end map() on a real net ------------------------
    # XLA programs are keyed on (L, T-bucket) shapes, which are hardware-
    # independent — a campaign compiles them once, so warm them untimed
    g = googlenet(1, scale=map_scale)
    clear_mapper_caches()
    PimMapper(PAPER_BEST, max_optim_iter=2, backend="batched").map(g)
    clear_mapper_caches()
    t0 = time.perf_counter()
    PimMapper(PAPER_BEST, max_optim_iter=2, backend="scalar").map(g)
    map_scalar_s = time.perf_counter() - t0
    clear_mapper_caches()
    t0 = time.perf_counter()
    PimMapper(PAPER_BEST, max_optim_iter=2, backend="batched").map(g)
    map_batched_s = time.perf_counter() - t0

    if assert_10x:
        assert speedup >= 10.0, (
            f"batched mapper candidate costing only {speedup:.1f}x faster "
            f"than scalar (contract: >=10x)")
    rate = n_sweeps * n_cands
    return [{
        "table": "mapper", "n_layers": len(layers), "region": list(region),
        "n_sweeps": n_sweeps, "cands_per_sweep": n_cands,
        "scalar_s": scalar_s, "batched_s": batched_s,
        "scalar_cands_per_s": rate / scalar_s,
        "batched_cands_per_s": rate / batched_s,
        "speedup": speedup,
        "map_scalar_s": map_scalar_s, "map_batched_s": map_batched_s,
        "map_speedup": map_scalar_s / map_batched_s,
    }]


def run_multi(batch: int = 8, map_scale: int = 4, seed: int = 0,
              best_of: int = 3, assert_3x: bool = True,
              min_speedup: float = 3.0,
              mapper_kwargs: dict | None = None) -> list[dict]:
    """Multi-config mode: ``map_many`` vs sequential per-config ``map()``.

    Two sequential baselines are timed, mirroring the single-config
    benchmark's scalar-vs-batched framing:

    * **scalar sequential** — one ``PimMapper(cfg, backend="scalar").map()``
      per config (the paper-faithful per-candidate reference loop,
      extrapolated from 3 configs).  The enforced contract (``assert_3x``,
      default outside smoke) is >=``min_speedup``x (3x) end-to-end map
      throughput against it at ``batch >= 8``.
    * **batched sequential** — one batched-backend ``map()`` per config with
      ``clear_mapper_caches()`` between configs (the memory-flat policy
      campaigns run today).  Reported unasserted, like the single-config
      ``map_speedup``: on CPU/interpret builds this ratio is modest (the
      per-shape python work is shared by both sides; only engine dispatches
      and within-batch shape reuse amortize), and is expected to widen on a
      real TPU backend where the fused multi-config dispatch dominates.

    Both sides are best-of-``best_of``, interleaved so slow-machine noise
    hits them equally, after an untimed warm-up of each side's XLA programs.
    """
    import numpy as np
    from repro.core.tuner import sample_configs
    assert batch >= 8, "the multi-config contract is defined at batch >= 8"
    g = googlenet(1, scale=map_scale)
    rng = np.random.default_rng(seed)
    cfgs = sample_configs(batch, rng)
    # one optimization pass at the mapper's shipped candidate-sweep defaults
    # (lm_cap=200, n_wr=5 — the paper-fidelity sweep width)
    kw = dict(max_optim_iter=1)
    kw.update(mapper_kwargs or {})

    # warm the XLA programs of every side (compile is one-off per process)
    clear_mapper_caches()
    PimMapper(cfgs[0], backend="batched", **kw).map_many(g, cfgs)
    for c in cfgs:
        clear_mapper_caches()
        PimMapper(c, backend="batched", **kw).map(g)
    clear_mapper_caches()
    PimMapper(cfgs[0], backend="scalar", **kw).map(g)

    def _timed(body):
        clear_mapper_caches()
        t0 = time.perf_counter()
        body()
        return time.perf_counter() - t0

    def seq_body():
        for c in cfgs:
            clear_mapper_caches()
            PimMapper(c, backend="batched", **kw).map(g)

    def batched_body():
        PimMapper(cfgs[0], backend="batched", **kw).map_many(g, cfgs)

    n_scalar = min(3, batch)

    def scalar_body():
        for c in cfgs[:n_scalar]:
            clear_mapper_caches()
            PimMapper(c, backend="scalar", **kw).map(g)

    seq_s = batched_s = scalar_s = float("inf")
    for _ in range(best_of):
        batched_s = min(batched_s, _timed(batched_body))
        scalar_s = min(scalar_s, _timed(scalar_body) * batch / n_scalar)
        seq_s = min(seq_s, _timed(seq_body))
    speedup_scalar = scalar_s / batched_s
    speedup_seq = seq_s / batched_s

    if assert_3x:
        assert speedup_scalar >= min_speedup, (
            f"multi-config mapping only {speedup_scalar:.2f}x faster than "
            f"sequential per-config (scalar) mapping at batch={batch} "
            f"(contract: >={min_speedup}x)")
    return [{
        "table": "mapper_multi", "batch": batch, "map_scale": map_scale,
        "seq_s": seq_s, "batched_s": batched_s, "scalar_seq_s": scalar_s,
        "maps_per_s_seq": batch / seq_s,
        "maps_per_s_batched": batch / batched_s,
        "speedup": speedup_scalar,
        "speedup_vs_batched_seq": speedup_seq,
    }]


def main(smoke: bool = False, multi: bool = False) -> None:
    if multi:
        # smoke: tiny net, soft 1.5x threshold — the full run enforces 3x
        r = run_multi(map_scale=8 if smoke else 4,
                      best_of=2 if smoke else 3,
                      min_speedup=1.5 if smoke else 3.0)[0]
        print(f"mapper_multi_seq,{1e6 * r['seq_s'] / r['batch']:.1f},"
              f"maps_per_s={r['maps_per_s_seq']:.2f}")
        print(f"mapper_multi_batched,{1e6 * r['batched_s'] / r['batch']:.1f},"
              f"maps_per_s={r['maps_per_s_batched']:.2f} "
              f"speedup={r['speedup']:.2f}x "
              f"vs_batched_seq={r['speedup_vs_batched_seq']:.2f}x")
        return
    if smoke:
        r = run(n_layers=8, n_sweeps=2, assert_10x=False, map_scale=8)[0]
    else:
        r = run()[0]
    print(f"mapper_scalar,{1e6 / r['scalar_cands_per_s']:.1f},"
          f"cands_per_s={r['scalar_cands_per_s']:.1f}")
    print(f"mapper_batched,{1e6 / r['batched_cands_per_s']:.1f},"
          f"cands_per_s={r['batched_cands_per_s']:.1f} "
          f"speedup={r['speedup']:.1f}x map_speedup={r['map_speedup']:.2f}x")


if __name__ == "__main__":
    main(smoke="--smoke" in sys.argv, multi="--multi-config" in sys.argv)
