"""End-to-end DSE-iteration throughput: device-resident pipeline vs staged.

Measures the PR 7 contract: a COLD scan-backend DSE campaign (the shape a
fresh tuning process actually runs) through ``run_dse(pipeline=True)`` —
the fused propose chain, deferred fits, cross-config scheduler prefill and
canonical bucket shapes — against the PR 6 staged path (per-stage host
round-trips, exact pow2 scheduler shapes, per-mapping prefill).

Framing
-------
Each side runs in its OWN subprocess (jit caches must not leak between
them).  A subprocess first runs the same campaign with
``scheduler_backend="loop"`` untimed: that warms every mapper / tuner /
batch-cost program while touching no scan-scheduler program, so the timed
phase isolates what the pipeline actually changes — scheduler program
count and per-iteration host synchronization — rather than re-measuring
the shared mapping work's first-compile storm.  Mapper memos are cleared
between phases; both sides then run the identical campaign cold on the
scan backend.

Contracts (asserted here, gated in CI via ``benchmarks.bench_gate`` on
``experiments/BENCH_7.json``):

* the fused and staged observation streams are IDENTICAL (the speedup is
  parity-pinned, not bought with different search results);
* fused / staged >= 2x end-to-end (``--smoke`` softens to 1.2x: CI workers
  are loaded and the smoke campaign is short);
* the fused run actually took the fused path (``fused_propose`` trace
  spans were recorded);
* the 16x16 / 960-link Fig. 12 array — the scheduler's memory-bound worst
  case — solves at >= 1x the loop reference on CPU (the
  ``scheduler_16x16_vs_loop`` gate; the Pallas streaming kernel targets
  TPU, the jnp dense path must at least break even on CPU).
"""

from __future__ import annotations

import argparse
import json
import subprocess
import sys
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(ROOT / "src"))

BENCH_ID = 7
BENCH_SCHEMA = "nicepim-bench/1"

MAPPER_KW = dict(max_optim_iter=1, lm_cap=40, n_wr=3)


# ---------------------------------------------------------------------------
# worker: one cold campaign in a fresh process
# ---------------------------------------------------------------------------


def worker(mode: str, iterations: int, n_sample: int) -> None:
    from repro.core.dse import WorkloadEvaluator, run_dse
    from repro.core.mapper import _sharing_latency, clear_mapper_caches
    from repro.core.tuner import PimTuner
    from repro.core.workloads import googlenet
    from repro.obs.trace import Tracer
    import repro.engine.scheduler_opt as so

    nets = [googlenet(1, scale=8)]
    pipeline = mode == "fused"

    def campaign(backend: str, tracer=None):
        ev = WorkloadEvaluator(nets, mapper_kwargs=MAPPER_KW,
                               scheduler_backend=backend)
        return run_dse(PimTuner(seed=0, n_sample=n_sample, backend="scan"),
                       ev, iterations=iterations, propose_k=8,
                       pipeline=pipeline, tracer=tracer)

    # phase 1 (untimed): warm the shared mapper/tuner/batch-cost programs
    # without compiling any scan-scheduler program
    campaign("loop")
    clear_mapper_caches()
    _sharing_latency.cache_clear()

    if mode == "staged":
        so._PAD_SHAPES = False        # the PR 6 exact-shape baseline
    tracer = Tracer()
    t0 = time.perf_counter()
    res = campaign("scan", tracer=tracer)
    dt = time.perf_counter() - t0

    stream = [(o.iteration, o.cfg.as_tuple(), o.area_mm2, o.legal, o.cost)
              for o in res.observations]
    fused_spans = sum(1 for ev in tracer.events()
                      if ev.get("name") == "fused_propose")
    print(json.dumps({
        "mode": mode, "secs": dt, "iterations": iterations,
        "sched_programs": so._scan_solve._cache_size(),
        "fused_spans": fused_spans, "stream": stream,
    }), flush=True)


def _run_worker(mode: str, iterations: int, n_sample: int) -> dict:
    cmd = [sys.executable, "-m", "benchmarks.pipeline_throughput",
           "--worker", mode, "--iters", str(iterations),
           "--n-sample", str(n_sample)]
    proc = subprocess.run(cmd, capture_output=True, text=True, cwd=ROOT)
    if proc.returncode != 0:
        raise RuntimeError(f"{mode} worker failed:\n{proc.stderr[-4000:]}")
    return json.loads(proc.stdout.strip().splitlines()[-1])


# ---------------------------------------------------------------------------
# the 16x16 memory-bound scheduler case (scheduler_16x16_vs_loop gate)
# ---------------------------------------------------------------------------


def _single_16x16(iters: int, seed: int = 0) -> dict:
    from benchmarks.scheduler_throughput import CHUNK, EPJ, FLIT_BW, FREQ, \
        fig12_problem
    from repro.core.scheduler import solve_ilp_ls
    from repro.engine.scheduler_opt import _USE_PALLAS

    noc, sets = fig12_problem(16, 4)
    chunks = [CHUNK] * len(sets)
    kw = dict(seed=seed, restarts=6, iters=iters)
    solve_ilp_ls(noc, sets, chunks, FLIT_BW, FREQ, EPJ,
                 backend="scan", **kw)                 # compile, untimed
    t0 = time.perf_counter()
    scan = solve_ilp_ls(noc, sets, chunks, FLIT_BW, FREQ, EPJ,
                        backend="scan", **kw)
    t_scan = time.perf_counter() - t0
    t0 = time.perf_counter()
    loop = solve_ilp_ls(noc, sets, chunks, FLIT_BW, FREQ, EPJ,
                        backend="loop", **kw)
    t_loop = time.perf_counter() - t0
    assert scan.max_link_bytes <= loop.max_link_bytes + 1e-9
    return {
        "table": "pipeline", "case": "single_16x16",
        "path": "pallas-stream" if _USE_PALLAS else "jnp-dense",
        "scan_s": t_scan, "loop_s": t_loop, "speedup": t_loop / t_scan,
    }


# ---------------------------------------------------------------------------
# orchestration
# ---------------------------------------------------------------------------


def run(iterations: int = 6, n_sample: int = 256,
        min_speedup: float = 2.0, sched_iters: int = 1200) -> list[dict]:
    fused = _run_worker("fused", iterations, n_sample)
    staged = _run_worker("staged", iterations, n_sample)

    assert fused["stream"] == staged["stream"], (
        "pipeline and staged DSE observation streams diverged — the "
        "speedup would not be parity-pinned")
    assert fused["fused_spans"] >= iterations, (
        f"only {fused['fused_spans']} fused_propose spans for "
        f"{iterations} iterations — the fused path was not taken")
    assert staged["fused_spans"] == 0, "staged run took the fused path"

    speedup = staged["secs"] / fused["secs"]
    rows = [{
        "table": "pipeline", "case": "dse_campaign",
        "iterations": iterations, "n_sample": n_sample,
        "fused_s": fused["secs"], "staged_s": staged["secs"],
        "iters_per_s_fused": iterations / fused["secs"],
        "iters_per_s_staged": iterations / staged["secs"],
        "fused_programs": fused["sched_programs"],
        "staged_programs": staged["sched_programs"],
        "speedup": speedup, "min_speedup": min_speedup,
        "parity": "match",
    }]
    assert speedup >= min_speedup, (
        f"device-resident pipeline only {speedup:.2f}x over the staged "
        f"path (contract: >={min_speedup}x)")

    single = _single_16x16(sched_iters)
    assert single["speedup"] >= 1.0, (
        f"16x16 scheduler case {single['speedup']:.2f}x vs loop — the "
        f"memory-bound case regressed below break-even")
    rows.append(single)
    return rows


SMOKE_KW = dict(iterations=4, n_sample=128, min_speedup=1.2,
                sched_iters=400)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="short campaign + soft thresholds (CI)")
    ap.add_argument("--worker", default=None, help="internal: run one side")
    ap.add_argument("--iters", type=int, default=None)
    ap.add_argument("--n-sample", type=int, default=None)
    ap.add_argument("--out", default=None, metavar="BENCH_7.json",
                    help="write the perf artifact here (default "
                         "experiments/BENCH_7.json)")
    args = ap.parse_args()

    if args.worker:
        worker(args.worker, args.iters, args.n_sample)
        return

    kw = dict(SMOKE_KW) if args.smoke else {}
    if args.iters is not None:
        kw["iterations"] = args.iters
    if args.n_sample is not None:
        kw["n_sample"] = args.n_sample
    t0 = time.time()
    rows = run(**kw)
    total_s = time.time() - t0

    r = rows[0]
    print(f"pipeline_staged,{1e6 * r['staged_s'] / r['iterations']:.0f},"
          f"iters_per_s={r['iters_per_s_staged']:.3f} "
          f"programs={r['staged_programs']}")
    print(f"pipeline_fused,{1e6 * r['fused_s'] / r['iterations']:.0f},"
          f"iters_per_s={r['iters_per_s_fused']:.3f} "
          f"programs={r['fused_programs']} "
          f"speedup={r['speedup']:.2f}x parity={r['parity']}")
    s = rows[1]
    print(f"pipeline_single_16x16,{s['scan_s'] * 1e6:.0f},"
          f"path={s['path']} speedup={s['speedup']:.2f}x")

    tol = 0.40 if args.smoke else 0.25
    bench = {
        "schema": BENCH_SCHEMA,
        "bench_id": BENCH_ID,
        "mode": "smoke" if args.smoke else "full",
        "generated_utc": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
        "sections_s": {"pipeline": total_s},
        "benchmarks": [
            {"name": "pipeline_fused",
             "us_per_call": 1e6 * r["fused_s"] / r["iterations"],
             "derived": f"speedup={r['speedup']:.2f}x"},
            {"name": "pipeline_single_16x16",
             "us_per_call": s["scan_s"] * 1e6,
             "derived": f"speedup={s['speedup']:.2f}x path={s['path']}"},
        ],
        "gates": {
            "pipeline_speedup": {"value": float(r["speedup"]),
                                 "tolerance": tol,
                                 "higher_is_better": True},
            "scheduler_16x16_vs_loop": {"value": float(s["speedup"]),
                                        "tolerance": tol,
                                        "higher_is_better": True},
        },
    }
    out = Path(args.out) if args.out else (
        ROOT / "experiments" / f"BENCH_{BENCH_ID}.json")
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(bench, indent=1) + "\n")
    print(f"# wrote {out}", flush=True)


if __name__ == "__main__":
    main()
