"""Fig. 10 reproduction: PIM-Mapper vs the sequential baseline.

Five workload DNNs at batch 1 on the paper's two evaluation systems
(4x4 array / 32x32 PEs / 128 KiB buffers and 16x16 array / 8x8 PEs /
8 KiB buffers).  Reports per-net latency+energy for both mappers and the
average reductions — the paper's headline is −37 % latency / −28 % energy.

``fast=True`` shrinks the nets (scale-4 spatial dims, 2-layer BERT) for CI.
"""

from __future__ import annotations

import time

from repro.core.baseline import BaselineMapper
from repro.core.hardware import PAPER_16X16, PAPER_4X4
from repro.core.mapper import PimMapper, evaluate_mapping
from repro.core.workloads import paper_workloads


def run(fast: bool = False, nets: list[str] | None = None) -> list[dict]:
    rows = []
    workloads = paper_workloads(1, fast=fast)
    if nets:
        workloads = [g for g in workloads if g.name in nets]
    for hw, sysname in ((PAPER_4X4, "4x4"), (PAPER_16X16, "16x16")):
        for g in workloads:
            t0 = time.time()
            rep = evaluate_mapping(PimMapper(hw).map(g))
            t_map = time.time() - t0
            t0 = time.time()
            base = evaluate_mapping(BaselineMapper(hw).map(g))
            t_base = time.time() - t0
            rows.append({
                "table": "fig10", "system": sysname, "net": g.name,
                "mapper_latency_ms": rep.latency_s * 1e3,
                "mapper_energy_uj": rep.energy_pj / 1e6,
                "baseline_latency_ms": base.latency_s * 1e3,
                "baseline_energy_uj": base.energy_pj / 1e6,
                "latency_reduction": 1 - rep.latency_s / base.latency_s,
                "energy_reduction": 1 - rep.energy_pj / base.energy_pj,
                "mapper_noc_uj": rep.energy_breakdown["noc"] / 1e6,
                "baseline_noc_uj": base.energy_breakdown["noc"] / 1e6,
                "mapper_dram_uj": rep.energy_breakdown["dram"] / 1e6,
                "baseline_dram_uj": base.energy_breakdown["dram"] / 1e6,
                "solve_s": t_map + t_base,
            })
    n = len(rows)
    rows.append({
        "table": "fig10", "system": "avg", "net": "all",
        "latency_reduction": sum(r["latency_reduction"]
                                 for r in rows[:n]) / n,
        "energy_reduction": sum(r["energy_reduction"] for r in rows[:n]) / n,
    })
    return rows


def main(fast: bool = True) -> None:
    for r in run(fast=fast):
        if r["net"] == "all":
            print(f"fig10_avg,,dLat={-r['latency_reduction']:.1%} "
                  f"dE={-r['energy_reduction']:.1%}")
        else:
            print(f"fig10_{r['system']}_{r['net']},"
                  f"{r['mapper_latency_ms'] * 1e3:.1f},"
                  f"dLat={-r['latency_reduction']:.1%} "
                  f"dE={-r['energy_reduction']:.1%}")


if __name__ == "__main__":
    main(fast=False)
